"""End-to-end multi-worker driver: 8 simulated workers run the full
GraphGen+ workflow — partitioning, balance table, edge-centric generation
with tree reduction, a TIERED device-resident hot-node feature cache
(a small replicated L1 holding the global Zipf head — probed with zero
network — in front of the sharded L2 where each worker holds the
authoritative shard of ``hash(id) mod W``, probed by one all_to_all round
before any owner fetch) threaded through the pipelined carry, synchronized
training, checkpointing, a simulated worker FAILURE, rebalancing over
survivors (the cache restarts cold — row ownership, the shard map
``hash(id) mod W``, AND the promoted L1 head all moved), and resume from
checkpoint.

    python examples/distributed_pipeline.py        (sets its own XLA_FLAGS)
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses          # noqa: E402
import tempfile             # noqa: E402

import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402
import numpy as np          # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.core.balance import balance_table             # noqa: E402
from repro.core.config import TrainConfig                # noqa: E402
from repro.core.feature_cache import CacheConfig         # noqa: E402
from repro.core.generation import make_distributed_generator  # noqa: E402
from repro.core.partition import partition_edges         # noqa: E402
from repro.core.pipeline import make_pipelined_step      # noqa: E402
from repro.graph.synthetic import node_features, powerlaw_graph  # noqa: E402
from repro.launch.mesh import make_mesh                  # noqa: E402
from repro.models import gcn                             # noqa: E402
from repro.train import checkpoint as ckpt               # noqa: E402
from repro.train.fault import recover_assignment         # noqa: E402
from repro.train.optimizer import adam_update, init_adam  # noqa: E402

N, DIM, CLASSES, B = 20_000, 64, 8, 16
FANOUTS = (8, 4)
# tiered 2-way cache: 8 workers x 1024 L2 rows = 8192 distinct sharded
# rows, plus a 128-row replicated L1 per worker serving the global head
# without even the probe round (rows promoted after 2 observations)
CACHE = CacheConfig(n_rows=1024, admit=2, assoc=2, mode="tiered",
                    l1_rows=128, l1_promote=2)
ckpt_dir = tempfile.mkdtemp(prefix="graphgen_ckpt_")


def build(workers: int):
    """(Re)build the distributed pipeline for a worker count — this is the
    elastic path used both at startup and after failures.  The hot-node
    cache starts empty on every (re)build: row ownership follows the new
    partitioning AND the shard map ``hash(id) mod W`` changed with W, so
    surviving state would be stale on both axes."""
    mesh = make_mesh((workers,), ("data",))
    part = partition_edges(graph, workers)
    gen_fn, dev, cache = make_distributed_generator(
        mesh, part, feats, labels, fanouts=FANOUTS, cache_cfg=CACHE)
    table = balance_table(np.arange(N), workers, seed=0)
    step = jax.jit(make_pipelined_step(gen_fn, train_fn, cached=True))
    return gen_fn, dev, table, step, cache


graph = powerlaw_graph(N, avg_degree=8, n_hot=20, hot_degree=1000, seed=0)
rng0 = np.random.default_rng(0)
feats = node_features(N, DIM)
labels = np.argmax(feats @ rng0.standard_normal((DIM, CLASSES)), 1).astype(np.int32)

cfg = dataclasses.replace(get_config("graphgen-gcn"), gcn_in_dim=DIM,
                          n_classes=CLASSES, gcn_hidden=128, fanouts=FANOUTS)
tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=0, total_steps=60)


def train_fn(params, opt, batch):
    loss, grads = jax.value_and_grad(gcn.gcn_loss)(params, batch)
    params, opt, _ = adam_update(tcfg, params, grads, opt)
    return params, opt, loss


params = gcn.init_gcn(cfg, jax.random.PRNGKey(0))
opt = init_adam(params)
workers = 8
gen_fn, dev, table, step, cache = build(workers)
rngs = jax.random.split(jax.random.PRNGKey(1), 200)


def seeds_for(table, t):
    per = table.per_worker
    cols = (np.arange(B) + t * B) % per.shape[1]
    return jnp.asarray(per[:, cols])


batch0, cache = gen_fn(dev, seeds_for(table, 0), rngs[0], cache)
carry = (params, opt, batch0, cache)
FAIL_AT, TOTAL = 20, 40
t = 0
while t < TOTAL:
    if t == FAIL_AT and workers == 8:
        print(f"\n*** step {t}: simulating loss of workers 3 and 6 ***")
        # survivors rebuild: Algorithm 1 re-runs over |W|-2, the graph is
        # re-partitioned, training resumes from the last durable checkpoint
        table = recover_assignment(table, failed=[3, 6])
        workers = table.n_workers  # 6 -> pad down to power-of-2 mesh
        workers = 4 if workers not in (1, 2, 4, 8) else workers
        table = balance_table(np.arange(N), workers, seed=2)
        gen_fn, dev, _, step, cache = build(workers)
        restore_t = ckpt.latest_step(ckpt_dir)
        params, opt = ckpt.restore(ckpt_dir, restore_t,
                                   (carry[0], carry[1]))
        batch0, cache = gen_fn(dev, seeds_for(table, restore_t),
                               rngs[restore_t], cache)
        carry = (params, opt, batch0, cache)
        t = restore_t
        print(f"*** resumed at step {t} on {workers} workers ***\n")
        continue
    carry, loss = step(carry, dev, seeds_for(table, t + 1), rngs[t + 1])
    if (t + 1) % 10 == 0:
        ckpt.save(ckpt_dir, t + 1, (carry[0], carry[1]), keep=3)
        print(f"step {t+1:3d}  loss {float(loss):.4f}  "
              f"workers={workers}  cache_hit={carry[2].cache_hit_rate():.2f}  "
              f"[checkpointed]")
    t += 1

print(f"\nfinished {TOTAL} steps across a simulated failure; "
      f"checkpoints in {ckpt_dir}")
