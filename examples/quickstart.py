"""Quickstart: GraphGen+ in ~60 lines.

Builds a synthetic power-law graph, partitions it (coordinator), assigns
seeds with the balance table, generates 2-hop subgraphs with the
edge-centric distributed sampler, and trains a GCN with the synchronized
generation+training pipeline — the paper's full workflow (Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.core.generation import make_distributed_generator
from repro.core.partition import partition_edges
from repro.core.pipeline import make_pipelined_step
from repro.graph.synthetic import node_features, powerlaw_graph
from repro.models import gcn
from repro.train.optimizer import adam_update, init_adam

N_NODES, N_CLASSES, DIM = 5_000, 8, 64
FANOUTS = (10, 5)       # 2-hop fanouts (paper uses 40, 20 at cluster scale);
                        # any depth works, e.g. (8,) or (15, 10, 5)
STEPS, BATCH = 30, 64

# ---- Step 1: Graph Partitioning (coordinator) -----------------------------
graph = powerlaw_graph(N_NODES, avg_degree=8, n_hot=10, hot_degree=500, seed=0)
mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
W = mesh.shape["data"]
part = partition_edges(graph, W, strategy="by_edge_hash")
print(f"graph: {graph.n_nodes} nodes / {graph.n_edges} edges, "
      f"{W} workers, edge balance {part.edge_balance():.3f}")

# features + labels with learnable structure
rng = np.random.default_rng(0)
feats = node_features(N_NODES, DIM)
labels = np.argmax(feats @ rng.standard_normal((DIM, N_CLASSES)), 1).astype(np.int32)

# ---- Step 2: Load-Balanced Subgraph Mapping --------------------------------
table = balance_table(np.arange(N_NODES), W, seed=0)
print(f"balance table: {table.seeds_per_worker} seeds/worker, "
      f"{table.n_discarded} discarded")

# ---- Step 3: Distributed (edge-centric) Subgraph Generation ---------------
gen_fn, device_args = make_distributed_generator(
    mesh, part, feats, labels, fanouts=FANOUTS)

# ---- Step 4: In-Memory Graph Learning (synchronized pipeline) --------------
import dataclasses
cfg = dataclasses.replace(get_config("graphgen-gcn"),
                          gcn_in_dim=DIM, n_classes=N_CLASSES,
                          gcn_hidden=128, fanouts=FANOUTS)
tcfg = TrainConfig(learning_rate=3e-3, total_steps=STEPS, warmup_steps=0)
params = gcn.init_gcn(cfg, jax.random.PRNGKey(0))
opt = init_adam(params)


def train_fn(params, opt, batch):
    loss, grads = jax.value_and_grad(gcn.gcn_loss)(params, batch)
    params, opt, _ = adam_update(tcfg, params, grads, opt)
    return params, opt, loss


step = jax.jit(make_pipelined_step(gen_fn, train_fn))
rngs = jax.random.split(jax.random.PRNGKey(1), STEPS + 1)
seeds = lambda t: jnp.asarray(
    table.per_worker[:, (t * BATCH) % (N_NODES - BATCH):][:, :BATCH])
carry = (params, opt, gen_fn(device_args, seeds(0), rngs[0]))
for t in range(STEPS):
    carry, loss = step(carry, device_args, seeds(t + 1), rngs[t + 1])
    if (t + 1) % 5 == 0:
        print(f"step {t+1:3d}  loss {float(loss):.4f}")
print("done — subgraphs were generated and consumed fully in memory.")
