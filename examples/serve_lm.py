"""Serve a small LM with batched requests through the zoo decode path.

Demonstrates the serving side of the framework on two cache disciplines:
a GQA KV-cache transformer (smollm) and an O(1)-state SSM (mamba2) — the
latter is the long_500k story at laptop scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, smoke_config
from repro.models import zoo

BATCH, PROMPT, GEN = 8, 12, 24

for arch in ("smollm-135m", "mamba2-1.3b"):
    cfg = smoke_config(REGISTRY[arch])
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(BATCH, PROMPT + GEN)
    decode = jax.jit(api.decode)

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (BATCH, PROMPT), dtype=np.int32)
    logits = None
    for p in range(PROMPT):
        logits, cache = decode(params, cache, jnp.asarray(prompt[:, p:p+1]),
                               jnp.int32(p))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = []
    t0 = time.perf_counter()
    for g in range(GEN):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.int32(PROMPT + g))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"{arch:16s} {BATCH * GEN / dt:8.1f} tok/s "
          f"(batch {BATCH})  sample: {gen[0][:10].tolist()}")
