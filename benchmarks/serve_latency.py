"""Serving-tier latency: p50/p99, sustained QPS, and the zero-recompile gate.

The serving tier's whole design — bucket ladder compiled at startup,
read-mostly cache, forward-only program — exists to keep re-JIT and
cache churn off the request path.  This benchmark drives the REAL serve
driver (``repro.launch.serve.serve_gcn``: bounded request queue,
producer thread, ``GraphServer``) over a synthetic Zipf request stream
and reports, per worker count:

  * ``p50_ms`` / ``p99_ms`` — end-to-end request latency percentiles
    (enqueue to predictions-on-host, queue wait included);
  * ``qps`` — sustained requests/second over the drained stream;
  * ``request_path_compiles`` — programs compiled AFTER startup warmup,
    read from the jit executable-cache probe
    (``repro.launch.serve.jit_compile_count``).

Gates ``main`` enforces on the W=4 smoke configuration:

  * **zero request-path recompiles** — every request must land on a
    bucket compiled at startup (the latency-killer claim, asserted
    exactly, not statistically);
  * **p99 tail bound** — ``p99 <= max(10 x p50, 50ms)``: a ratio, not an
    absolute time, so the gate survives runner-speed drift while still
    catching a bimodal tail (a stray compile, a host sync, a cold
    bucket).

Each cell runs in a FRESH interpreter (``--cell``), the same
measurement hygiene as ``benchmarks/host_fetch.py``: cells measured in
one process inherit allocator and JIT-cache state and are not
comparable.

    PYTHONPATH=src python -m benchmarks.serve_latency [--smoke] \
        [--workers N] [--requests K] [--out BENCH_serve_latency.json]

Emits the ``name,us_per_call,derived`` CSV rows the harness expects
(``us_per_call`` is the cell's p50 request latency).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def _cell_env(workers: int) -> dict:
    """Child-process environment for one cell: the forced host device
    count must be in ``XLA_FLAGS`` before the child imports jax."""
    env = dict(os.environ)
    if workers > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={workers} "
            + env.get("XLA_FLAGS", ""))
    return env


def _run_cell(spec: dict) -> dict:
    """Run one :func:`measure` cell in a fresh interpreter (the
    host_fetch hygiene rule: cells sharing a process inherit each
    other's allocator and JIT-cache state and bias later cells slow)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_latency",
           "--cell", json.dumps(spec)]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          env=_cell_env(spec.get("workers", 4)))
    if proc.returncode != 0:
        raise RuntimeError(f"cell {spec} failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure(*, workers: int = 4, nodes: int = 8_192, requests: int = 160,
            buckets: str = "8,16,32", warmup_sweeps: int = 4,
            queue_depth: int = 32, seed: int = 0) -> dict:
    """One cell: the full serve driver (queue + producer thread + bucket
    ladder + read-mostly cache) over a Zipf request stream.

    Runs ``repro.launch.serve.serve_gcn`` exactly as the CLI would — the
    benchmark measures the driver users run, not a stripped-down
    stand-in — and returns its result record (p50/p99/qps/compile
    counts) tagged with the cell configuration."""
    from repro.launch.serve import serve_gcn

    args = argparse.Namespace(
        arch="graphgen-gcn", smoke=True, seed=seed, workers=workers,
        nodes=nodes, avg_degree=10.0, buckets=buckets, requests=requests,
        queue_depth=queue_depth, warmup_sweeps=warmup_sweeps,
        warmup_head=0, warm_from=None)
    rec = serve_gcn(args)
    rec.update(workers=workers, nodes=nodes, buckets=buckets)
    return rec


def sweep(*, smoke: bool = False, workers: int = 4, requests: int = None,
          seed: int = 0) -> dict:
    """W=1 and W=``workers`` serve cells, each in a fresh interpreter.

    The W=1 cell is the no-collectives floor (probe and fetch are local
    gathers); the W=``workers`` cell pays the frozen probe round and is
    the configuration the CI gates check."""
    nodes = 8_192 if smoke else 65_536
    requests = requests or (160 if smoke else 512)
    cells = [1] + ([workers] if workers > 1 else [])
    results = [
        _run_cell(dict(workers=w, nodes=nodes, requests=requests,
                       seed=seed))
        for w in cells
    ]
    return {
        "benchmark": "serve_latency",
        "workers": workers,
        "nodes": nodes,
        "requests": requests,
        "results": results,
    }


def bench() -> list:
    """Harness entry (benchmarks.run): smoke-size sweep, CSV rows
    (``us_per_call`` is the p50 request latency)."""
    rec = sweep(smoke=True, workers=1)
    return [
        (f"serve_latency_w{r['workers']}", r["p50_ms"] * 1e3,
         f"p99_ms={r['p99_ms']:.2f},qps={r['qps']:.1f},"
         f"request_compiles={r['request_path_compiles']}")
        for r in rec["results"]
    ]


def main() -> None:
    """CLI: run the sweep, print CSV rows, enforce the serve gates."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (the CI configuration)")
    ap.add_argument("--workers", type=int, default=4,
                    help="forced host devices for the gated cell "
                         "(the W=4 smoke configuration)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--cell", default=None,
                    help="(internal) measure one cell from a JSON spec "
                         "and print its result — how sweep() isolates "
                         "cells in fresh interpreters")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(measure(**json.loads(args.cell))))
        return

    rec = sweep(smoke=args.smoke, workers=args.workers,
                requests=args.requests, seed=args.seed)
    print("name,us_per_call,derived")
    for r in rec["results"]:
        print(f"serve_latency_w{r['workers']},{r['p50_ms'] * 1e3:.1f},"
              f"p99_ms={r['p99_ms']:.2f},qps={r['qps']:.1f},"
              f"request_compiles={r['request_path_compiles']},"
              f"startup_compiles={r['startup_compiles']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)

    failed = False
    for r in rec["results"]:
        # the zero-recompile gate: exact, per cell — one request landing
        # on an uncompiled shape is a ladder bug, not noise
        if r["request_path_compiles"] != 0:
            print(f"WARNING: W={r['workers']} served requests on "
                  f"{r['request_path_compiles']} uncompiled shapes — "
                  f"the bucket ladder must cover the request stream",
                  file=sys.stderr)
            failed = True
        # the tail gate: ratio-based so runner drift cannot flip it; the
        # 50ms floor keeps sub-ms-p50 cells from failing on scheduler
        # jitter alone
        bound = max(10.0 * r["p50_ms"], 50.0)
        if r["p99_ms"] > bound:
            print(f"WARNING: W={r['workers']} p99 {r['p99_ms']:.2f}ms > "
                  f"bound {bound:.2f}ms (max(10 x p50, 50ms)) — the "
                  f"latency tail is bimodal",
                  file=sys.stderr)
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
