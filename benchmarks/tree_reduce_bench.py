"""Paper §2(3): tree reduction vs flat (all-to-root) aggregation for
hot-node candidate merging.

Wall time is measured on 8 forced-host devices in a subprocess (the main
process keeps 1 device).  The derived column also reports the analytic
per-worker traffic: flat root ingests (W-1)*K candidate rows, the butterfly
moves log2(W)*K per worker — the reason hot nodes stop being a bottleneck.
"""
from __future__ import annotations

import math
import os
import subprocess
import sys
import textwrap

_CODE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.generation import Candidates, merge_topk
from repro.core.tree_reduce import tree_allreduce
from repro.launch.mesh import make_mesh

W, F, K = 8, 4096, 40
mesh = make_mesh((W,), ('data',))
rng = np.random.default_rng(0)
ids = jnp.asarray(rng.integers(0, 1_000_000, (W, F, K), dtype=np.int32))
keys = jnp.asarray(rng.uniform(0, 1, (W, F, K)).astype(np.float32))

def tree(i, k):
    return tree_allreduce(Candidates(i[0], k[0]), merge_topk, 'data').ids

def flat(i, k):
    # all-gather everything to every worker, then a single wide merge
    gi = jax.lax.all_gather(i[0], 'data')            # [W, F, K]
    gk = jax.lax.all_gather(k[0], 'data')
    cand = Candidates(jnp.moveaxis(gi, 0, -1).reshape(F, K * W),
                      jnp.moveaxis(gk, 0, -1).reshape(F, K * W))
    neg, idx = jax.lax.top_k(-cand.keys, K)
    return jnp.take_along_axis(cand.ids, idx, axis=-1)

run_tree = jax.jit(shard_map(tree, mesh=mesh, in_specs=(P('data'), P('data')),
                             out_specs=P('data'), check_rep=False))
run_flat = jax.jit(shard_map(flat, mesh=mesh, in_specs=(P('data'), P('data')),
                             out_specs=P('data'), check_rep=False))
for f in (run_tree, run_flat):
    jax.block_until_ready(f(ids, keys))
out = {}
for name, f in (('tree', run_tree), ('flat', run_flat)):
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(f(ids, keys))
        ts.append(time.perf_counter() - t0)
    out[name] = sorted(ts)[2] * 1e6
# equivalence of results (same candidate multiset -> same min-K keys)
a = np.sort(np.asarray(run_tree(ids, keys)), axis=-1)
b = np.sort(np.asarray(run_flat(ids, keys)), axis=-1)
assert (a == b).all(), 'tree and flat merges disagree'
print(f"{out['tree']:.1f} {out['flat']:.1f}")
"""


def bench() -> list[tuple]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                          capture_output=True, text=True, timeout=600, env=env)
    if proc.returncode != 0:
        return [("tree_reduce", 0.0, f"ERROR:{proc.stderr[-200:]}")]
    t_tree, t_flat = map(float, proc.stdout.split())
    w, k = 8, 40
    return [
        ("tree_reduce_butterfly", t_tree,
         f"per_worker_rows={int(math.log2(w))*k}"),
        ("tree_reduce_flat_gather", t_flat,
         f"per_worker_rows={(w-1)*k};speedup={t_flat/t_tree:.2f}x"),
    ]
