"""L3 host feature store: steady-state step time, device vs host placement.

The paper's 530M-node feature table does not fit aggregate device
memory; the L3 tier (``core/host_store.py``) keeps it in host RAM and
resolves misses through an asynchronous gather that rides under the
next step's generation compute (the issue/collect split).  This
benchmark measures the cost of that decoupling END TO END — the full
generate / issue / patch+train dispatch sequence ``pipelined_loop``
runs — not the gather in isolation, because the overlap claim is about
what the *loop* pays, not what the transfer costs.

The sweep scales the feature table to 1x / 2x / 4x a nominal device
budget and measures three placements per size:

  * ``device``   — the table lives on device, misses resolve through
                   the routed owner ``all_to_all`` (the baseline; at
                   4x this configuration is exactly what capacity
                   makes impossible on real hardware);
  * ``host d2``  — host table, ``host_gather_depth=2``: the gather
                   runs on the store's worker thread and overlaps the
                   step (overlap-ON);
  * ``host d1``  — host table, ``host_gather_depth=1``: the gather
                   blocks at issue time (overlap-OFF — what a naive
                   host store would pay).

Gates ``main`` enforces on the W=4 smoke configuration, at the 4x
table (the size device memory cannot hold — the configuration the
whole tier exists for):

  * overlapped host step time <= 1.15x the device baseline (the
    decoupling-is-affordable claim);
  * overlap-on strictly faster than overlap-off (the double buffer
    actually hides the transfer).

Two measurement-hygiene rules keep the comparisons honest:

  * every cell runs in a FRESH interpreter (``sweep`` shells out to
    ``--cell``): cells measured in one process inherit each other's
    allocator and JIT-cache state, which biases later cells slow by
    10%+ — more than the effect under test;
  * each cell times ``repeats`` independent blocks of ``iters`` steps
    and keeps the MINIMUM block time, so a contention spike cannot
    flip a gate.

The overlap gate is additionally hardware-aware: thread overlap needs
a spare core to run on, so on a single-core runner (where wall time
equals total work and depth 2 cannot win by construction) the d2/d1
comparison is reported but not enforced.

    PYTHONPATH=src python -m benchmarks.host_fetch [--smoke] \
        [--workers N] [--iters K] [--out BENCH_host_fetch.json] \
        [--baseline benchmarks/baselines/host_fetch_smoke_w4.json]

Emits the ``name,us_per_call,derived`` CSV rows the harness expects.
``--baseline`` compares each table scale's host/device step-time RATIOS
against a checked-in reference (ratios, not absolute times — the
nightly runner's clock is not this machine's) and fails on a >20%
relative regression.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

TABLE_SCALES = (1, 2, 4)


def _cell_env(workers: int) -> dict:
    """Child-process environment for one cell: the forced host device
    count must be in ``XLA_FLAGS`` before the child imports jax."""
    env = dict(os.environ)
    if workers > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={workers} "
            + env.get("XLA_FLAGS", ""))
    return env


def _run_cell(spec: dict) -> dict:
    """Run one :func:`measure` cell in a fresh interpreter.

    Cells measured back-to-back in one process are NOT comparable: the
    later cell inherits the earlier one's allocator fragmentation and
    JIT-cache footprint and runs 10%+ slower from that alone.  Shelling
    out to ``--cell`` gives every cell identical cold-process conditions,
    which is what lets the gates compare cells at all."""
    cmd = [sys.executable, "-m", "benchmarks.host_fetch",
           "--cell", json.dumps(spec)]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          env=_cell_env(spec.get("workers", 4)))
    if proc.returncode != 0:
        raise RuntimeError(f"cell {spec} failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure(*, scale: int, store: str, depth: int = 2, workers: int = 4,
            base_nodes: int = 8_192, dim: int = 256, batch: int = 96,
            fanouts=(10, 5), hidden: int = 64, iters: int = 12,
            warmup: int = 5, repeats: int = 3, seed: int = 0) -> dict:
    """Steady-state per-step wall time of the pipelined loop, one config.

    Builds the real distributed generator over a power-law graph with
    ``scale * base_nodes`` nodes (the feature table scales with it),
    compiles the pipelined step once, runs ``warmup`` steps outside the
    clock, then times ``repeats`` blocks of ``iters`` steady-state steps
    each — blocking only at block boundaries — and reports the fastest
    block.  The min-of-blocks estimator is deliberate: the gates compare
    cells measured seconds apart, and a single contention spike in a
    shared runner would otherwise dominate the mean.  The dispatch
    regime inside a block is exactly the launcher's loop, so the host
    path's issue/collect overlap (or, at depth 1, its absence) is what
    the clock sees."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import dataclasses
    from repro.configs import REGISTRY, smoke_config
    from repro.core.balance import balance_table
    from repro.core.config import TrainConfig
    from repro.core.generation import make_distributed_generator
    from repro.core.partition import partition_edges
    from repro.core.pipeline import (make_host_consume_step,
                                     make_pipelined_step)
    from repro.graph.synthetic import (node_features, node_labels,
                                       powerlaw_graph)
    from repro.launch.mesh import make_mesh
    from repro.models import gcn as gcn_mod
    from repro.train.optimizer import adam_update, init_adam

    host = store == "host"
    n_nodes = scale * base_nodes
    mesh = make_mesh((workers,), ("data",))
    g = powerlaw_graph(n_nodes, avg_degree=8, n_hot=8, hot_degree=400,
                       seed=seed)
    part = partition_edges(g, workers)
    feats = node_features(n_nodes, dim, seed, features_on_host=host)
    labels = node_labels(n_nodes, 16, seed)

    out = make_distributed_generator(
        mesh, part, feats, labels, fanouts=tuple(fanouts),
        feature_store=store, host_gather_depth=depth)
    if host:
        gen_fn, device_args, fstore = out
    else:
        gen_fn, device_args = out
        fstore = None

    cfg = dataclasses.replace(
        smoke_config(REGISTRY["graphgen-gcn"]),
        gcn_in_dim=dim, gcn_hidden=hidden, n_classes=16,
        fanouts=tuple(fanouts))
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(seed))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=iters + warmup)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n_nodes), workers, seed=seed)
    n_steps = warmup + repeats * iters + 1
    sched = [
        jnp.asarray(table.per_worker[:, (t * batch) % (n_nodes // workers
                                                       - batch):][:, :batch])
        for t in range(n_steps)
    ]
    rngs = jax.random.split(jax.random.PRNGKey(seed + 1), n_steps + 1)

    # mirror pipelined_loop's dispatch exactly: host mode splits gen and
    # patch+train so the gather rides between them; device mode runs the
    # fused pipelined step
    pending = None
    if host:
        consume = jax.jit(make_host_consume_step(train_fn))
        batch0, req = gen_fn(device_args, sched[0], rngs[0])
        carry = (params, opt, batch0, req)
        pending = fstore.issue(req.ids)
    else:
        step = jax.jit(make_pipelined_step(gen_fn, train_fn))
        batch0 = gen_fn(device_args, sched[0], rngs[0])
        carry = (params, opt, batch0)

    def run_step(t):
        nonlocal carry, pending
        if host:
            landed = pending.rows()
            nb, nreq = gen_fn(device_args, sched[t], rngs[t])
            pending = fstore.issue(nreq.ids)
            p, o, loss = consume(carry[0], carry[1], carry[2], carry[3],
                                 landed)
            carry = (p, o, nb, nreq)
        else:
            carry, loss = step(carry, device_args, sched[t], rngs[t])
        return loss

    for t in range(1, warmup + 1):
        loss = run_step(t)
    jax.block_until_ready(loss)
    t = warmup + 1
    blocks = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            loss = run_step(t)
            t += 1
        jax.block_until_ready(loss)
        blocks.append(time.perf_counter() - t0)
    us = min(blocks) / iters * 1e6
    return {
        "scale": scale,
        "store": store,
        "depth": depth if host else None,
        "n_nodes": n_nodes,
        "table_mb": feats.nbytes / 1e6,
        "us_per_step": us,
        "host_gather_mb": (fstore.bytes_issued / 1e6) if host else 0.0,
    }


def sweep(*, smoke: bool = False, workers: int = 4, iters: int = None,
          seed: int = 0) -> dict:
    """Device vs host (overlap on/off) step time at 1x/2x/4x table scale.

    Each scale runs three cells over the SAME graph/schedule/rng stream
    — the device baseline, host with the double buffer (depth 2), host
    with the blocking gather (depth 1) — every cell in its own fresh
    interpreter (see :func:`_run_cell`).  ``host_over_device`` and
    ``overlap_speedup`` are the two ratios the gates and the checked-in
    baseline track."""
    base_nodes = 8_192 if smoke else 65_536
    dim = 256
    iters = iters or (12 if smoke else 40)
    results = []
    for scale in TABLE_SCALES:
        common = dict(scale=scale, workers=workers, base_nodes=base_nodes,
                      dim=dim, iters=iters, seed=seed)
        dev = _run_cell(dict(common, store="device"))
        d2 = _run_cell(dict(common, store="host", depth=2))
        d1 = _run_cell(dict(common, store="host", depth=1))
        d2["host_over_device"] = d2["us_per_step"] / max(dev["us_per_step"],
                                                         1e-9)
        d1["host_over_device"] = d1["us_per_step"] / max(dev["us_per_step"],
                                                         1e-9)
        d2["overlap_speedup"] = d1["us_per_step"] / max(d2["us_per_step"],
                                                        1e-9)
        results += [dev, d2, d1]
    return {
        "benchmark": "host_fetch",
        "workers": workers,
        "base_nodes": base_nodes,
        "dim": dim,
        "iters": iters,
        "results": results,
    }


def _row_name(r: dict) -> str:
    name = f"host_fetch_{r['scale']}x_{r['store']}"
    if r["store"] == "host":
        name += f"_d{r['depth']}"
    return name


def check_baseline(rec: dict, baseline: dict, tol: float = 0.20) -> list:
    """Compare each scale's host/device RATIOS against a checked-in
    reference; return failure strings for any cell whose ratio grew more
    than ``tol`` relative (the nightly regression gate).  Ratios — not
    absolute step times — so the gate survives runner-speed drift; cells
    missing on either side are skipped."""
    def key(r):
        return (r["scale"], r["store"], r.get("depth"))

    have = {key(r): r for r in rec["results"]}
    failures = []
    for b in baseline.get("results", []):
        if "host_over_device" not in b:
            continue
        now = have.get(key(b))
        if now is None or "host_over_device" not in now:
            continue
        ceil = b["host_over_device"] * (1.0 + tol)
        if now["host_over_device"] > ceil:
            failures.append(
                f"{_row_name(b)}: host_over_device "
                f"{now['host_over_device']:.3f} > baseline "
                f"{b['host_over_device']:.3f} + {tol:.0%}")
    return failures


def bench() -> list:
    """Harness entry (benchmarks.run): smoke-size sweep, CSV rows."""
    rec = sweep(smoke=True, workers=1)
    rows = []
    for r in rec["results"]:
        derived = f"table_mb={r['table_mb']:.1f}"
        if "host_over_device" in r:
            derived += f",host_over_device={r['host_over_device']:.3f}"
        if "overlap_speedup" in r:
            derived += f",overlap_speedup={r['overlap_speedup']:.3f}"
        rows.append((_row_name(r), float(r["us_per_step"]), derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (the CI configuration)")
    ap.add_argument("--workers", type=int, default=4,
                    help="forced host devices (the W=4 smoke gate "
                         "configuration)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timed steady-state steps per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--baseline", default=None,
                    help="checked-in baseline JSON; fail if any scale's "
                         "host/device ratio regresses >20%% relative")
    ap.add_argument("--cell", default=None,
                    help="(internal) measure one cell from a JSON spec "
                         "and print its result — how sweep() isolates "
                         "cells in fresh interpreters")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(measure(**json.loads(args.cell))))
        return

    rec = sweep(smoke=args.smoke, workers=args.workers, iters=args.iters,
                seed=args.seed)
    print("name,us_per_call,derived")
    for r in rec["results"]:
        line = (f"{_row_name(r)},{r['us_per_step']:.1f},"
                f"table_mb={r['table_mb']:.1f}")
        if "host_over_device" in r:
            line += f",host_over_device={r['host_over_device']:.3f}"
        if "overlap_speedup" in r:
            line += f",overlap_speedup={r['overlap_speedup']:.3f}"
        if r["store"] == "host":
            line += f",host_gather_mb={r['host_gather_mb']:.1f}"
        print(line)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)

    failed = False
    cells = {(r["scale"], r["store"], r.get("depth")): r
             for r in rec["results"]}
    # the affordability gate: at the table size device memory cannot hold
    # (4x), the overlapped host store costs at most 15% step time
    big = cells.get((4, "host", 2))
    dev = cells.get((4, "device", None))
    if big and dev and big["us_per_step"] > 1.15 * dev["us_per_step"]:
        print(f"WARNING: overlapped host step "
              f"{big['us_per_step']:.0f}us > 1.15x device baseline "
              f"{dev['us_per_step']:.0f}us at 4x table",
              file=sys.stderr)
        failed = True
    # the overlap gate: at 4x the double buffer must actually hide the
    # gather (smaller scales report overlap_speedup but do not gate —
    # their gather is light enough that the edge sits inside runner
    # noise).  Enforced only where overlap is physically possible: on a
    # single-core runner wall time equals total work, so depth 2 cannot
    # beat depth 1 by construction.
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:
        cores = os.cpu_count() or 1
    d1 = cells.get((4, "host", 1))
    if cores < 2:
        print("NOTE: single-core runner — overlap gate not enforced "
              "(no spare core to overlap on; ratios reported above)",
              file=sys.stderr)
    elif big and d1 and big["us_per_step"] >= d1["us_per_step"]:
        print(f"WARNING: overlap-on {big['us_per_step']:.0f}us >= "
              f"overlap-off {d1['us_per_step']:.0f}us at 4x table",
              file=sys.stderr)
        failed = True
    if args.baseline:
        with open(args.baseline) as f:
            base_rec = json.load(f)
        for msg in check_baseline(rec, base_rec):
            print(f"REGRESSION: {msg}", file=sys.stderr)
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
