"""Paper §3 headline: subgraph-generation throughput.

Compares the three generation strategies on the same graph and sampling
task (default: the paper's 2-hop (40, 20) fanouts; the driver is
depth-generic):

  * GraphGen+ edge-centric (parallel gather over the edge array)
  * traditional SQL-like  (per-hop JOIN against the full edge table)  — 27x
  * AGL node-centric      (serial per-node neighbor walk)             — hot-node bound

and reports nodes/second plus the speedup ratios.  ``--scale`` runs the
1M-nodes-per-iteration configuration (paper: "supports training on
1 million nodes per iteration").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.baselines import (edge_centric_sample, node_centric_sample,
                                  sql_like_sample)
from repro.graph.synthetic import powerlaw_graph

from repro.graph.subgraph import slots_per_seed

from .common import time_fn


def _multi_hop(sampler, indptr, indices, seeds, fanouts, rng):
    """L-hop expansion: each hop samples from the previous hop's flattened
    ids (depth-generic version of the paper's 2-hop task)."""
    rngs = jax.random.split(rng, max(len(fanouts), 2))
    frontier = seeds
    out = []
    for level, k in enumerate(fanouts):
        ids, m = sampler(indptr, indices, frontier, k, rngs[level])
        out.append((ids, m))
        frontier = ids.reshape(-1)
    return out


def bench(scale: bool = False, fanouts: tuple = (40, 20)) -> list[tuple]:
    n_nodes = 20_000 if not scale else 60_000
    n_seeds = 256 if not scale else 1_189           # 1189*(1+40+800) > 1M
    g = powerlaw_graph(n_nodes, avg_degree=10, n_hot=n_nodes // 500,
                       hot_degree=2_000, seed=0)
    indptr = jnp.asarray(g.indptr)
    indices = jnp.asarray(g.indices)
    src, dst = g.edge_list()
    src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
    seeds = jnp.arange(n_seeds, dtype=jnp.int32)
    rng = jax.random.PRNGKey(0)
    nodes_per_iter = n_seeds * slots_per_seed(fanouts)

    edge = jax.jit(lambda s, r: _multi_hop(
        lambda ip, ix, f, k, rr: edge_centric_sample(indptr, indices, f, k, rr),
        indptr, indices, s, fanouts, r))
    t_edge = time_fn(edge, seeds, rng)

    rows = [
        (f"gen_edge_centric{'_1M' if scale else ''}", t_edge,
         f"nodes_per_s={nodes_per_iter / (t_edge/1e6):,.0f}"
         + (f";nodes_per_iter={nodes_per_iter:,}" if scale else "")),
    ]
    if scale:
        # the serial baselines are intractable at this size on one CPU core
        # (the point of the comparison is already made at default scale)
        return rows

    max_deg = int(g.degrees().max())
    node = jax.jit(lambda s, r: _multi_hop(
        lambda ip, ix, f, k, rr: node_centric_sample(
            indptr, indices, f, k, rr, max_degree=max_deg),
        indptr, indices, s, fanouts, r))
    t_node = time_fn(node, seeds, rng, warmup=1, iters=3)
    rows.append(
        ("gen_node_centric_agl", t_node,
         f"speedup_edge_vs_agl={t_node / t_edge:.1f}x(maxdeg={max_deg})"))
    if not scale:
        sql = jax.jit(lambda s, r: _multi_hop(
            lambda ip, ix, f, k, rr: sql_like_sample(src_j, dst_j, f, k, rr),
            indptr, indices, s, fanouts, r))
        t_sql = time_fn(sql, seeds, rng, warmup=1, iters=3)
        rows.append(("gen_sql_like", t_sql,
                     f"speedup_edge_vs_sql={t_sql / t_edge:.1f}x(paper=27x)"))
    return rows
