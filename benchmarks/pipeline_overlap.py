"""Paper §2(4): synchronized generation+training vs the offline GraphGen
baseline (precompute -> storage round-trip -> train).  The paper reports a
1.3x end-to-end win for the synchronized pipeline; here the storage cost is
physically paid as device->host serialization (DESIGN.md §2)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.balance import balance_table
from repro.core.config import TrainConfig
from repro.core.generation import make_distributed_generator
from repro.core.partition import partition_edges
from repro.core.pipeline import offline_loop, pipelined_loop
from repro.graph.synthetic import node_features, node_labels, powerlaw_graph
from repro.models import gcn as gcn_mod
from repro.train.optimizer import adam_update, init_adam
from jax.sharding import Mesh


def bench() -> list[tuple]:
    import dataclasses
    from repro.configs import REGISTRY

    n, dim, classes = 8_000, 128, 16
    k1, k2 = 10, 5
    steps = 12
    b = 128
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    g = powerlaw_graph(n, avg_degree=10, seed=0)
    part = partition_edges(g, 1)
    feats = node_features(n, dim)
    labels = node_labels(n, classes)
    gen, dev = make_distributed_generator(mesh, part, feats, labels,
                                          fanouts=(k1, k2))
    cfg = dataclasses.replace(REGISTRY["graphgen-gcn"],
                              gcn_in_dim=dim, n_classes=classes,
                              gcn_hidden=256, fanouts=(k1, k2))
    params = gcn_mod.init_gcn(cfg, jax.random.PRNGKey(0))
    opt = init_adam(params)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=steps)

    def train_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn_mod.gcn_loss)(params, batch)
        params, opt, _ = adam_update(tcfg, params, grads, opt)
        return params, opt, loss

    table = balance_table(np.arange(n), 1, seed=0)
    sched = np.stack(
        [table.per_worker[:, (i * b) % (n - b):(i * b) % (n - b) + b]
         for i in range(steps)]
    )
    rng = jax.random.PRNGKey(1)

    # pre-jit both step functions and warm them up (compile excluded)
    from repro.core.pipeline import make_pipelined_step
    step = jax.jit(make_pipelined_step(gen, train_fn))
    train_step = jax.jit(train_fn)
    pipelined_loop(gen, train_fn, dev, sched[:2], params, opt, rng, step=step,
                   train_step=train_step)
    offline_loop(gen, train_fn, dev, sched[:2], params, opt, rng,
                 train_step=train_step)

    t0 = time.perf_counter()
    pipelined_loop(gen, train_fn, dev, sched, params, opt, rng, step=step,
                   train_step=train_step)
    t_pipe = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, _, _, stats = offline_loop(gen, train_fn, dev, sched, params, opt, rng,
                                  train_step=train_step)
    t_off = time.perf_counter() - t0

    return [
        ("pipeline_graphgen_plus", t_pipe / steps * 1e6,
         f"end_to_end_speedup={t_off / t_pipe:.2f}x(paper=1.3x)"),
        ("pipeline_offline_graphgen", t_off / steps * 1e6,
         f"gen_s={stats['t_gen']:.2f};train_s={stats['t_train']:.2f}"),
    ]
