"""Hot-node feature cache: wire-slot reduction vs cache size on Zipf skew,
and the three-way replicated / sharded / tiered placement sweep at equal
per-worker capacity.

Industrial graphs are power-law; a Zipf(1.1) request stream is the
canonical stand-in for the id mix a fanout sampler presents to the feature
shuffle.  PR 1's dedup already collapses duplicates *within* an iteration;
this benchmark measures what the cross-iteration cache tier removes on top:
the number of distinct ids that still go to their owner
(``FetchStats.n_unique`` summed over the run) as a function of
``cache_rows``, plus the steady-state hit rate and bytes saved.

With ``--workers > 1`` every TOTAL per-worker row budget is additionally
measured in **sharded** placement (cache-aware routing: ids probe the
worker whose CACHE shard owns them before falling through to the row
owner) and **tiered** placement (a replicated L1 head in front of the
sharded L2; equal-total split — the only power-of-two partition of a
power-of-two budget — is half L1, half L2).  Each replica of a replicated
cache converges on the same Zipf head, so total distinct capacity stays
~C; the sharded cache partitions the id-space and reaches W*C; the tiered
cache trades half the L2 capacity for serving the global head with ZERO
probe-round traffic.

Both probe-round modes are measured under BOTH wire formats
(``CacheConfig.wire``): a **dense** pass first (full [W, cap, D] response
block — it also observes ``CacheStats.probe_hit_peak``, the largest
per-destination hit count any holder produced), then a **compact** pass
with ``hit_cap`` sized to that peak plus a margin (mirroring the
launcher's calibration ladder).  ``probe_round_bytes`` is MEASURED — the
sum of ``FetchStats.probe_round_bytes``, i.e. the byte size of the
exchange buffers the compiled program actually ships — not an
occupied-slot estimate.  Gates ``main`` enforces at ``--workers > 1``:

  * compact probe bytes strictly below dense for BOTH sharded and tiered
    at every size, AND the reduction is at least the probe round's
    measured miss fraction (the compact claim: response bytes scale with
    hits, and on this stream most probe slots are not hits);
  * tiered compact probe bytes strictly below sharded compact at equal
    total rows (the L1 filter keeps the head off the round, so its hit
    peak — and therefore its payload — is smaller);
  * sharded hits strictly above replicated per size; the L1 serves
    >= 20% of tiered hits network-free.

    PYTHONPATH=src python -m benchmarks.feature_cache [--smoke] \
        [--out BENCH_feature_cache.json] [--workers N] [--iters K] \
        [--baseline benchmarks/baselines/feature_cache_smoke_w4.json]

Emits the ``name,us_per_call,derived`` CSV rows the benchmark harness
expects and (with ``--out``) a JSON artifact so CI can accumulate the perf
trajectory.  ``--baseline`` compares each (size, mode, wire) cell's
unique_reduction against a checked-in reference and fails on a >5%
relative regression (the nightly job's gate).  Acceptance anchors: at
``cache_rows=4096`` on Zipf(1.1) over >= 20 iterations the routed-unique
reduction vs cache-off is >= 30%, plus the wire gates above.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

CACHE_SIZES = (1024, 4096, 16384)
SMOKE_SIZES = (1024, 4096)


_ZIPF_P = {}


def zipf_requests(rng, n_nodes: int, size: int, a: float = 1.1):
    """Bounded Zipf(a) ids over [0, n_nodes) (rank 0 = hottest node).

    Proper truncated-zeta sampling — folding ``rng.zipf`` mod n would
    redistribute the unbounded tail *uniformly*, burying the cacheable
    head under synthetic noise no real power-law graph has."""
    import numpy as np
    key = (n_nodes, a)
    if key not in _ZIPF_P:
        p = np.arange(1, n_nodes + 1, dtype=np.float64) ** -a
        _ZIPF_P[key] = p / p.sum()
    return rng.choice(n_nodes, size=size, p=_ZIPF_P[key]).astype(np.int32)


def measure(n_nodes: int, dim: int, requests: int, iters: int,
            cache_rows: int, *, admit: int = 2, assoc: int = 1,
            mode: str = "replicated", l1_rows: int = 0, l1_promote: int = 2,
            wire: str = "dense", hit_cap: int = 0,
            zipf_a: float = 1.1, seed: int = 0, workers: int = 1,
            time_it: bool = False) -> dict:
    """Run ``iters`` cached fetches over a Zipf stream; count routed uniques.

    Runs the REAL ``fetch_rows`` path under shard_map (the all_to_all
    routes between ``workers`` devices when more than one is forced), so
    ``FetchStats.n_unique`` is the number of ids that genuinely went — or,
    at W=1, would go — to their owner, and ``probe_round_bytes`` is the
    byte size of the buffers the probe round actually shipped.  Every
    worker draws its own iid Zipf stream (distinct per-worker request
    mixes are exactly what separates sharded from replicated placement).
    Counters are summed over ALL workers except ``probe_hit_peak``, which
    is max-reduced (it bounds the ``hit_cap`` a compact response needs).
    ``cache_rows`` is the main-tier (L2) size; tiered mode adds
    ``l1_rows`` replicated L1 slots, so total per-worker rows are
    ``cache_rows + l1_rows``.  ``wire``/``hit_cap`` select the probe-round
    response format (``CacheConfig.wire``; dense here by default so the
    sweep's first pass can observe the hit peak the compact pass needs).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.feature_cache import CacheConfig, init_cache_state
    from repro.core.generation import fetch_rows
    from repro.launch.mesh import make_mesh
    from .common import time_fn

    mesh = make_mesh((workers,), ("data",))
    rows_pw = -(-n_nodes // workers)
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((workers * rows_pw, dim)).astype(np.float32)
    cached = cache_rows > 0
    cfg = CacheConfig(n_rows=cache_rows, admit=admit, assoc=assoc,
                      mode=mode, l1_rows=l1_rows if mode == "tiered" else 0,
                      l1_promote=l1_promote, wire=wire,
                      hit_cap=hit_cap).validated() if cached else None

    # each worker fetches rows for ITS OWN stream, so the fetched block is
    # per-worker data — it must leave the shard_map sharded, not stamped
    # replicated (check_rep=False would mask the mismatch silently)
    if cached:
        def worker(t, i, c):
            c = jax.tree.map(lambda a: a[0], c)
            out, c, fs, cs = fetch_rows(t, i[0], "data", cache=c,
                                        cache_cfg=cfg)
            c = jax.tree.map(lambda a: a[None], c)
            stats = jax.tree.map(lambda a: a[None], (fs, cs))
            return out[None], c, stats

        run = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data")), check_rep=False))
        state = jax.device_put(
            init_cache_state(cfg, dim, workers),
            NamedSharding(mesh, P("data")))
    else:
        def worker_nc(t, i):
            out, fs = fetch_rows(t, i[0], "data", return_stats=True)
            return out[None], jax.tree.map(lambda a: a[None], fs)

        run = jax.jit(shard_map(
            worker_nc, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data")), check_rep=False))
        state = None

    table_j = jnp.asarray(table)
    # one iid Zipf stream PER WORKER per iteration, stacked [W, R] and
    # sharded so each worker presents its own request mix
    spec = NamedSharding(mesh, P("data"))
    streams = [jax.device_put(jnp.asarray(np.stack(
        [zipf_requests(rng, n_nodes, requests, zipf_a)
         for _ in range(workers)])), spec) for _ in range(iters)]
    sum_unique = 0
    sum_hits = 0
    sum_local_hits = 0
    sum_l1_hits = 0
    sum_bytes_saved = 0
    probe_round_bytes = 0
    probe_demoted = 0
    probe_hit_peak = 0
    dropped = 0
    for ids in streams:
        if cached:
            out, state, (fs, cs) = run(table_j, ids, state)
            sum_hits += int(np.asarray(cs.n_hits).sum())
            sum_l1_hits += int(np.asarray(cs.n_l1_hits).sum())
            sum_local_hits += int(np.asarray(cs.n_local_hits).sum())
            sum_bytes_saved += int(np.asarray(cs.bytes_saved).sum())
            probe_demoted += int(np.asarray(cs.n_probe_demoted).sum())
            probe_hit_peak = max(probe_hit_peak,
                                 int(np.asarray(cs.probe_hit_peak).max()))
            # MEASURED: the byte size of the buffers every worker actually
            # shipped on the shard-probe all_to_all this iteration
            probe_round_bytes += int(np.asarray(fs.probe_round_bytes).sum())
        else:
            out, fs = run(table_j, ids)
        sum_unique += int(np.asarray(fs.n_unique).sum())
        dropped += int(np.asarray(fs.n_dropped).sum())
    rec = {
        "cache_rows": cache_rows,
        "l1_rows": l1_rows if (cached and mode == "tiered") else 0,
        "total_rows": cache_rows + (l1_rows if (cached and mode == "tiered")
                                    else 0),
        "admit": admit,
        "assoc": assoc,
        "mode": mode if cached else None,
        "wire": (wire if (cached and mode in ("sharded", "tiered")
                          and workers > 1) else None),
        "hit_cap": hit_cap if cached else 0,
        "sum_n_unique": sum_unique,
        "sum_hits": sum_hits,
        "sum_l1_hits": sum_l1_hits,
        "sum_local_hits": sum_local_hits,
        "sum_shard_hits": sum_hits - sum_local_hits - sum_l1_hits,
        "sum_bytes_saved": sum_bytes_saved,
        "probe_round_bytes": probe_round_bytes,
        "probe_demoted": probe_demoted,
        "probe_hit_peak": probe_hit_peak,
        "dropped": dropped,
        "hit_rate": sum_hits / max(sum_hits + sum_unique, 1),
    }
    if time_it:
        if cached:
            rec["us_per_fetch"] = time_fn(
                lambda: run(table_j, streams[0], state))
        else:
            rec["us_per_fetch"] = time_fn(lambda: run(table_j, streams[0]))
    return rec


def calibrated_hit_cap(peak: int) -> int:
    """Compact payload bound from a dense pass's observed hit peak.

    Peak plus a ~12% skew margin (floored at 8 rows): the compact pass
    must not demote on the same stream the peak was measured on, but a
    bound tracking the peak tightly is exactly what makes the response
    scale with hits."""
    return max(peak + max(peak // 8, 8), 1)


def sweep(*, smoke: bool = False, workers: int = 1, iters: int = None,
          seed: int = 0, assoc: int = 2, time_it: bool = False) -> dict:
    """Three-way placement sweep at EQUAL total per-worker rows, each
    probe-round mode under both wire formats.

    Every swept size ``c`` is the TOTAL per-worker row budget: replicated
    and sharded spend all of it on their single tier; tiered splits it
    half L1 / half L2 (the only power-of-two partition of a power-of-two
    budget — both tiers hash with the top-bits trick, so both must be
    powers of two).  Sharded/tiered cells run twice: a dense pass that
    also observes the per-destination hit peak, then a compact pass with
    ``hit_cap = calibrated_hit_cap(peak)`` — the same peak-plus-margin
    policy the launcher's ladder converges to."""
    n_nodes = 20_000 if smoke else 200_000
    dim = 32 if smoke else 128
    requests = 4_096 if smoke else 16_384
    iters = iters or (20 if smoke else 50)
    sizes = SMOKE_SIZES if smoke else CACHE_SIZES
    base = measure(n_nodes, dim, requests, iters, 0, seed=seed,
                   workers=workers, time_it=time_it)
    results = [base]
    modes = (("replicated", "sharded", "tiered") if workers > 1
             else ("replicated",))
    for c in sizes:
        for mode in modes:
            l2 = c // 2 if mode == "tiered" else c
            l1 = c // 2 if mode == "tiered" else 0
            rec = measure(n_nodes, dim, requests, iters, l2, seed=seed,
                          assoc=assoc, mode=mode, l1_rows=l1,
                          workers=workers, time_it=time_it)
            rec["unique_reduction"] = 1.0 - rec["sum_n_unique"] / max(
                base["sum_n_unique"], 1)
            results.append(rec)
            if rec["wire"] is None:
                continue        # no probe round -> nothing to compact
            hc = calibrated_hit_cap(rec["probe_hit_peak"])
            crec = measure(n_nodes, dim, requests, iters, l2, seed=seed,
                           assoc=assoc, mode=mode, l1_rows=l1,
                           wire="compact", hit_cap=hc,
                           workers=workers, time_it=time_it)
            crec["unique_reduction"] = 1.0 - crec["sum_n_unique"] / max(
                base["sum_n_unique"], 1)
            results.append(crec)
    return {
        "benchmark": "feature_cache",
        "zipf_a": 1.1,
        "n_nodes": n_nodes,
        "dim": dim,
        "requests_per_iter": requests,
        "iters": iters,
        "workers": workers,
        "assoc": assoc,
        "results": results,
    }


def _row_name(r: dict) -> str:
    name = f"feature_cache_rows_{r['total_rows']}"
    if r.get("mode"):
        name += f"_{r['mode']}"
    if r.get("wire"):
        name += f"_{r['wire']}"
    return name


def check_baseline(rec: dict, baseline: dict, tol: float = 0.05) -> list:
    """Compare each (total_rows, mode, wire) cell's unique_reduction
    against a checked-in baseline; return failure strings for any cell
    whose reduction fell more than ``tol`` RELATIVE (the nightly
    regression gate).  Cells missing on either side are skipped — adding
    a new size, mode, or wire must not fail the old baseline."""
    def key(r):
        return (r.get("total_rows"), r.get("mode"), r.get("wire"))

    have = {key(r): r for r in rec["results"] if r.get("mode")}
    failures = []
    for b in baseline.get("results", []):
        if not b.get("mode") or "unique_reduction" not in b:
            continue
        now = have.get(key(b))
        if now is None:
            continue
        floor = b["unique_reduction"] * (1.0 - tol)
        if now["unique_reduction"] < floor:
            failures.append(
                f"{_row_name(b)}: unique_reduction "
                f"{now['unique_reduction']:.3f} < baseline "
                f"{b['unique_reduction']:.3f} - {tol:.0%}")
    return failures


def bench() -> list:
    """Harness entry (benchmarks.run): smoke-size sweep, CSV rows."""
    rec = sweep(smoke=True)
    rows = []
    for r in rec["results"]:
        derived = (f"routed_unique={r['sum_n_unique']}"
                   f",hit_rate={r['hit_rate']:.3f}")
        if "unique_reduction" in r:
            derived += f",unique_reduction={r['unique_reduction']:.3f}"
        rows.append((_row_name(r), float(r.get("us_per_fetch", 0.0)), derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (the CI configuration)")
    ap.add_argument("--workers", type=int, default=1,
                    help="forced host devices; >1 exercises the real "
                         "all_to_all routing AND the sharded-mode sweep")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--assoc", type=int, default=2, choices=[1, 2, 4])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--time", action="store_true",
                    help="also time each fetch variant")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--baseline", default=None,
                    help="checked-in baseline JSON; fail if any mode's "
                         "unique_reduction regresses >5%% relative")
    args = ap.parse_args()
    if args.workers > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.workers} "
            + os.environ.get("XLA_FLAGS", ""))

    rec = sweep(smoke=args.smoke, workers=args.workers, iters=args.iters,
                seed=args.seed, assoc=args.assoc, time_it=args.time)
    print("name,us_per_call,derived")
    for r in rec["results"]:
        red = r.get("unique_reduction")
        line = (f"{_row_name(r)},"
                f"{r.get('us_per_fetch', 0.0):.1f},"
                f"routed_unique={r['sum_n_unique']}"
                f",hit_rate={r['hit_rate']:.3f}")
        if red is not None:
            line += f",unique_reduction={red:.3f}"
        if r.get("wire"):
            line += (f",wire={r['wire']}"
                     f",probe_round_bytes={r['probe_round_bytes']}")
            if r["wire"] == "compact":
                line += (f",hit_cap={r['hit_cap']}"
                         f",demoted={r['probe_demoted']}")
        if r.get("mode") == "tiered":
            line += (f",l1_hit_share="
                     f"{r['sum_l1_hits'] / max(r['sum_hits'], 1):.3f}")
        print(line)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)
    failed = False
    at4096 = [r for r in rec["results"]
              if r["cache_rows"] == 4096 and r.get("mode") == "replicated"]
    if at4096 and at4096[0].get("unique_reduction", 0.0) < 0.30:
        print("WARNING: <30% routed-unique reduction at cache_rows=4096",
              file=sys.stderr)
        failed = True
    if args.workers > 1:
        cells = {}
        for r in rec["results"]:
            if r.get("mode"):
                cells[(r["total_rows"], r["mode"], r.get("wire"))] = r
        for c in sorted({k[0] for k in cells}):
            rep = cells.get((c, "replicated", None))
            sh_d = cells.get((c, "sharded", "dense"))
            sh_c = cells.get((c, "sharded", "compact"))
            ti_d = cells.get((c, "tiered", "dense"))
            ti_c = cells.get((c, "tiered", "compact"))
            # the sharded claim: strictly more unique hits than replication
            # at EQUAL total per-worker rows, for every swept size
            if rep and sh_d and sh_d["sum_hits"] <= rep["sum_hits"]:
                print(f"WARNING: sharded hits {sh_d['sum_hits']} <= "
                      f"replicated {rep['sum_hits']} at total_rows={c}",
                      file=sys.stderr)
                failed = True
            # the compact-wire claim, per probe-round mode: MEASURED bytes
            # strictly below dense, by at least the probe round's miss
            # fraction (the response is the dominant direction, and only
            # its hit slots carry data)
            for mode, d, k in (("sharded", sh_d, sh_c),
                               ("tiered", ti_d, ti_c)):
                if not (d and k):
                    continue
                if k["probe_round_bytes"] >= d["probe_round_bytes"]:
                    print(f"WARNING: {mode} compact probe bytes "
                          f"{k['probe_round_bytes']} >= dense "
                          f"{d['probe_round_bytes']} at total_rows={c}",
                          file=sys.stderr)
                    failed = True
                # ids the probe round carried = hits it served (L1 hits
                # never enter it) + misses; the miss fraction of THOSE
                carried = (d["sum_hits"] - d["sum_l1_hits"]
                           + d["sum_n_unique"])
                miss_frac = d["sum_n_unique"] / max(carried, 1)
                reduction = 1.0 - (k["probe_round_bytes"]
                                   / max(d["probe_round_bytes"], 1))
                if reduction < miss_frac:
                    print(f"WARNING: {mode} compact reduction "
                          f"{reduction:.1%} < probe-round miss fraction "
                          f"{miss_frac:.1%} at total_rows={c}",
                          file=sys.stderr)
                    failed = True
            # the tiered claim: the L1 head keeps distinct ids OFF the
            # probe round, so its hit peak — and therefore its compact
            # payload — stays strictly below sharded at equal total rows,
            # with the L1 serving >= 20% of all hits without any network
            if sh_c and ti_c:
                if ti_c["probe_round_bytes"] >= sh_c["probe_round_bytes"]:
                    print(f"WARNING: tiered compact probe bytes "
                          f"{ti_c['probe_round_bytes']} >= sharded "
                          f"{sh_c['probe_round_bytes']} at total_rows={c}",
                          file=sys.stderr)
                    failed = True
            if ti_d:
                l1_share = ti_d["sum_l1_hits"] / max(ti_d["sum_hits"], 1)
                if l1_share < 0.20:
                    print(f"WARNING: L1 serves only {l1_share:.1%} of tiered "
                          f"hits at total_rows={c} (need >= 20%)",
                          file=sys.stderr)
                    failed = True
    if args.baseline:
        with open(args.baseline) as f:
            base_rec = json.load(f)
        for msg in check_baseline(rec, base_rec):
            print(f"REGRESSION: {msg}", file=sys.stderr)
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
