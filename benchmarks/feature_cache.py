"""Hot-node feature cache: wire-slot reduction vs cache size on Zipf skew,
and the three-way replicated / sharded / tiered placement sweep at equal
per-worker capacity.

Industrial graphs are power-law; a Zipf(1.1) request stream is the
canonical stand-in for the id mix a fanout sampler presents to the feature
shuffle.  PR 1's dedup already collapses duplicates *within* an iteration;
this benchmark measures what the cross-iteration cache tier removes on top:
the number of distinct ids that still go to their owner
(``FetchStats.n_unique`` summed over the run) as a function of
``cache_rows``, plus the steady-state hit rate and bytes saved.

With ``--workers > 1`` every TOTAL per-worker row budget is additionally
measured in **sharded** placement (cache-aware routing: ids probe the
worker whose CACHE shard owns them before falling through to the row
owner) and **tiered** placement (a replicated L1 head in front of the
sharded L2; equal-total split — the only power-of-two partition of a
power-of-two budget — is half L1, half L2).  Each replica of a replicated
cache converges on the same Zipf head, so total distinct capacity stays
~C; the sharded cache partitions the id-space and reaches W*C; the tiered
cache trades half the L2 capacity for serving the global head with ZERO
probe-round traffic.  ``probe_round_bytes`` counts the ids each mode
actually carries on the shard-probe all_to_all (occupied wire slots x
(id up + hit flag and row down) — what a compacted transport would ship;
empty slack slots carry only the -1 sentinel): sharded ships EVERY
distinct id, tiered only the L1 misses, so at equal total rows the tiered
probe round is strictly cheaper (the gate ``main`` enforces, together
with the L1 serving >= 20% of all hits network-free).

    PYTHONPATH=src python -m benchmarks.feature_cache [--smoke] \
        [--out BENCH_feature_cache.json] [--workers N] [--iters K] \
        [--baseline benchmarks/baselines/feature_cache_smoke_w4.json]

Emits the ``name,us_per_call,derived`` CSV rows the benchmark harness
expects and (with ``--out``) a JSON artifact so CI can accumulate the perf
trajectory.  ``--baseline`` compares each mode's unique_reduction against
a checked-in reference and fails on a >5% relative regression (the
nightly job's gate).  Acceptance anchors: at ``cache_rows=4096`` on
Zipf(1.1) over >= 20 iterations the routed-unique reduction vs cache-off
is >= 30%; at ``--workers > 1`` sharded hits strictly exceed replicated
hits per size, tiered probe-round bytes stay strictly below sharded, and
the L1 serves >= 20% of tiered hits.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

CACHE_SIZES = (1024, 4096, 16384)
SMOKE_SIZES = (1024, 4096)


_ZIPF_P = {}


def zipf_requests(rng, n_nodes: int, size: int, a: float = 1.1):
    """Bounded Zipf(a) ids over [0, n_nodes) (rank 0 = hottest node).

    Proper truncated-zeta sampling — folding ``rng.zipf`` mod n would
    redistribute the unbounded tail *uniformly*, burying the cacheable
    head under synthetic noise no real power-law graph has."""
    import numpy as np
    key = (n_nodes, a)
    if key not in _ZIPF_P:
        p = np.arange(1, n_nodes + 1, dtype=np.float64) ** -a
        _ZIPF_P[key] = p / p.sum()
    return rng.choice(n_nodes, size=size, p=_ZIPF_P[key]).astype(np.int32)


def measure(n_nodes: int, dim: int, requests: int, iters: int,
            cache_rows: int, *, admit: int = 2, assoc: int = 1,
            mode: str = "replicated", l1_rows: int = 0, l1_promote: int = 2,
            zipf_a: float = 1.1, seed: int = 0, workers: int = 1,
            time_it: bool = False) -> dict:
    """Run ``iters`` cached fetches over a Zipf stream; count routed uniques.

    Runs the REAL ``fetch_rows`` path under shard_map (the all_to_all
    routes between ``workers`` devices when more than one is forced), so
    ``FetchStats.n_unique`` is the number of ids that genuinely went — or,
    at W=1, would go — to their owner.  Every worker draws its own iid
    Zipf stream (distinct per-worker request mixes are exactly what
    separates sharded from replicated placement).  Counters are summed
    over ALL workers.  ``cache_rows`` is the main-tier (L2) size; tiered
    mode adds ``l1_rows`` replicated L1 slots, so total per-worker rows
    are ``cache_rows + l1_rows``.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.feature_cache import CacheConfig, init_cache_state
    from repro.core.generation import fetch_rows
    from repro.launch.mesh import make_mesh
    from .common import time_fn

    mesh = make_mesh((workers,), ("data",))
    rows_pw = -(-n_nodes // workers)
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((workers * rows_pw, dim)).astype(np.float32)
    cached = cache_rows > 0
    cfg = CacheConfig(n_rows=cache_rows, admit=admit, assoc=assoc,
                      mode=mode, l1_rows=l1_rows if mode == "tiered" else 0,
                      l1_promote=l1_promote).validated() if cached else None

    # each worker fetches rows for ITS OWN stream, so the fetched block is
    # per-worker data — it must leave the shard_map sharded, not stamped
    # replicated (check_rep=False would mask the mismatch silently)
    if cached:
        def worker(t, i, c):
            c = jax.tree.map(lambda a: a[0], c)
            out, c, fs, cs = fetch_rows(t, i[0], "data", cache=c,
                                        cache_cfg=cfg)
            c = jax.tree.map(lambda a: a[None], c)
            stats = jax.tree.map(lambda a: a[None], (fs, cs))
            return out[None], c, stats

        run = jax.jit(shard_map(
            worker, mesh=mesh, in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data")), check_rep=False))
        state = jax.device_put(
            init_cache_state(cfg, dim, workers),
            NamedSharding(mesh, P("data")))
    else:
        def worker_nc(t, i):
            out, fs = fetch_rows(t, i[0], "data", return_stats=True)
            return out[None], jax.tree.map(lambda a: a[None], fs)

        run = jax.jit(shard_map(
            worker_nc, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data")), check_rep=False))
        state = None

    table_j = jnp.asarray(table)
    # one iid Zipf stream PER WORKER per iteration, stacked [W, R] and
    # sharded so each worker presents its own request mix
    spec = NamedSharding(mesh, P("data"))
    streams = [jax.device_put(jnp.asarray(np.stack(
        [zipf_requests(rng, n_nodes, requests, zipf_a)
         for _ in range(workers)])), spec) for _ in range(iters)]
    sum_unique = 0
    sum_hits = 0
    sum_local_hits = 0
    sum_l1_hits = 0
    sum_bytes_saved = 0
    probe_round_ids = 0
    dropped = 0
    for ids in streams:
        if cached:
            out, state, (fs, cs) = run(table_j, ids, state)
            n_hits = int(np.asarray(cs.n_hits).sum())
            n_l1 = int(np.asarray(cs.n_l1_hits).sum())
            n_miss = int(np.asarray(cs.n_misses).sum())
            sum_hits += n_hits
            sum_l1_hits += n_l1
            sum_local_hits += int(np.asarray(cs.n_local_hits).sum())
            sum_bytes_saved += int(np.asarray(cs.bytes_saved).sum())
            if mode in ("sharded", "tiered"):
                # ids this mode carried on the shard-probe round: every
                # distinct id (= hits + misses, by conservation) minus the
                # L1 hits that never left the requester
                probe_round_ids += n_hits + n_miss - n_l1
        else:
            out, fs = run(table_j, ids)
        sum_unique += int(np.asarray(fs.n_unique).sum())
        dropped += int(np.asarray(fs.n_dropped).sum())
    # per probed id: the int32 id rides out, a hit byte and the [D] f32
    # row ride back (what a compacted probe transport would ship)
    probe_slot_bytes = 4 + 1 + 4 * dim
    rec = {
        "cache_rows": cache_rows,
        "l1_rows": l1_rows if (cached and mode == "tiered") else 0,
        "total_rows": cache_rows + (l1_rows if (cached and mode == "tiered")
                                    else 0),
        "admit": admit,
        "assoc": assoc,
        "mode": mode if cached else None,
        "sum_n_unique": sum_unique,
        "sum_hits": sum_hits,
        "sum_l1_hits": sum_l1_hits,
        "sum_local_hits": sum_local_hits,
        "sum_shard_hits": sum_hits - sum_local_hits - sum_l1_hits,
        "sum_bytes_saved": sum_bytes_saved,
        "probe_round_ids": probe_round_ids,
        "probe_round_bytes": probe_round_ids * probe_slot_bytes,
        "dropped": dropped,
        "hit_rate": sum_hits / max(sum_hits + sum_unique, 1),
    }
    if time_it:
        if cached:
            rec["us_per_fetch"] = time_fn(
                lambda: run(table_j, streams[0], state))
        else:
            rec["us_per_fetch"] = time_fn(lambda: run(table_j, streams[0]))
    return rec


def sweep(*, smoke: bool = False, workers: int = 1, iters: int = None,
          seed: int = 0, assoc: int = 2, time_it: bool = False) -> dict:
    """Three-way placement sweep at EQUAL total per-worker rows.

    Every swept size ``c`` is the TOTAL per-worker row budget: replicated
    and sharded spend all of it on their single tier; tiered splits it
    half L1 / half L2 (the only power-of-two partition of a power-of-two
    budget — both tiers hash with the top-bits trick, so both must be
    powers of two)."""
    n_nodes = 20_000 if smoke else 200_000
    dim = 32 if smoke else 128
    requests = 4_096 if smoke else 16_384
    iters = iters or (20 if smoke else 50)
    sizes = SMOKE_SIZES if smoke else CACHE_SIZES
    base = measure(n_nodes, dim, requests, iters, 0, seed=seed,
                   workers=workers, time_it=time_it)
    results = [base]
    modes = (("replicated", "sharded", "tiered") if workers > 1
             else ("replicated",))
    for c in sizes:
        for mode in modes:
            l2 = c // 2 if mode == "tiered" else c
            l1 = c // 2 if mode == "tiered" else 0
            rec = measure(n_nodes, dim, requests, iters, l2, seed=seed,
                          assoc=assoc, mode=mode, l1_rows=l1,
                          workers=workers, time_it=time_it)
            rec["unique_reduction"] = 1.0 - rec["sum_n_unique"] / max(
                base["sum_n_unique"], 1)
            results.append(rec)
    return {
        "benchmark": "feature_cache",
        "zipf_a": 1.1,
        "n_nodes": n_nodes,
        "dim": dim,
        "requests_per_iter": requests,
        "iters": iters,
        "workers": workers,
        "assoc": assoc,
        "results": results,
    }


def _row_name(r: dict) -> str:
    name = f"feature_cache_rows_{r['total_rows']}"
    if r.get("mode"):
        name += f"_{r['mode']}"
    return name


def check_baseline(rec: dict, baseline: dict, tol: float = 0.05) -> list:
    """Compare each (total_rows, mode) cell's unique_reduction against a
    checked-in baseline; return failure strings for any cell whose
    reduction fell more than ``tol`` RELATIVE (the nightly regression
    gate).  Cells missing on either side are skipped — adding a new size
    or mode must not fail the old baseline."""
    def key(r):
        return (r.get("total_rows"), r.get("mode"))

    have = {key(r): r for r in rec["results"] if r.get("mode")}
    failures = []
    for b in baseline.get("results", []):
        if not b.get("mode") or "unique_reduction" not in b:
            continue
        now = have.get(key(b))
        if now is None:
            continue
        floor = b["unique_reduction"] * (1.0 - tol)
        if now["unique_reduction"] < floor:
            failures.append(
                f"{_row_name(b)}: unique_reduction "
                f"{now['unique_reduction']:.3f} < baseline "
                f"{b['unique_reduction']:.3f} - {tol:.0%}")
    return failures


def bench() -> list:
    """Harness entry (benchmarks.run): smoke-size sweep, CSV rows."""
    rec = sweep(smoke=True)
    rows = []
    for r in rec["results"]:
        derived = (f"routed_unique={r['sum_n_unique']}"
                   f",hit_rate={r['hit_rate']:.3f}")
        if "unique_reduction" in r:
            derived += f",unique_reduction={r['unique_reduction']:.3f}"
        rows.append((_row_name(r), float(r.get("us_per_fetch", 0.0)), derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (the CI configuration)")
    ap.add_argument("--workers", type=int, default=1,
                    help="forced host devices; >1 exercises the real "
                         "all_to_all routing AND the sharded-mode sweep")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--assoc", type=int, default=2, choices=[1, 2, 4])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--time", action="store_true",
                    help="also time each fetch variant")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--baseline", default=None,
                    help="checked-in baseline JSON; fail if any mode's "
                         "unique_reduction regresses >5%% relative")
    args = ap.parse_args()
    if args.workers > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.workers} "
            + os.environ.get("XLA_FLAGS", ""))

    rec = sweep(smoke=args.smoke, workers=args.workers, iters=args.iters,
                seed=args.seed, assoc=args.assoc, time_it=args.time)
    print("name,us_per_call,derived")
    for r in rec["results"]:
        red = r.get("unique_reduction")
        line = (f"{_row_name(r)},"
                f"{r.get('us_per_fetch', 0.0):.1f},"
                f"routed_unique={r['sum_n_unique']}"
                f",hit_rate={r['hit_rate']:.3f}")
        if red is not None:
            line += f",unique_reduction={red:.3f}"
        if r.get("mode") in ("sharded", "tiered"):
            line += f",probe_round_bytes={r['probe_round_bytes']}"
        if r.get("mode") == "tiered":
            line += (f",l1_hit_share="
                     f"{r['sum_l1_hits'] / max(r['sum_hits'], 1):.3f}")
        print(line)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)
    failed = False
    at4096 = [r for r in rec["results"]
              if r["cache_rows"] == 4096 and r.get("mode") == "replicated"]
    if at4096 and at4096[0].get("unique_reduction", 0.0) < 0.30:
        print("WARNING: <30% routed-unique reduction at cache_rows=4096",
              file=sys.stderr)
        failed = True
    if args.workers > 1:
        by_size = {}
        for r in rec["results"]:
            if r.get("mode"):
                by_size.setdefault(r["total_rows"], {})[r["mode"]] = r
        for c, recs in sorted(by_size.items()):
            rep, sh = recs.get("replicated"), recs.get("sharded")
            ti = recs.get("tiered")
            # the sharded claim: strictly more unique hits than replication
            # at EQUAL total per-worker rows, for every swept size
            if rep and sh and sh["sum_hits"] <= rep["sum_hits"]:
                print(f"WARNING: sharded hits {sh['sum_hits']} <= replicated "
                      f"{rep['sum_hits']} at total_rows={c}", file=sys.stderr)
                failed = True
            # the tiered claim: the L1 head keeps distinct ids OFF the
            # probe round — strictly fewer probe-round bytes than sharded
            # at equal total rows, with the L1 serving >= 20% of all hits
            # without any network at all
            if sh and ti:
                if ti["probe_round_bytes"] >= sh["probe_round_bytes"]:
                    print(f"WARNING: tiered probe bytes "
                          f"{ti['probe_round_bytes']} >= sharded "
                          f"{sh['probe_round_bytes']} at total_rows={c}",
                          file=sys.stderr)
                    failed = True
                l1_share = ti["sum_l1_hits"] / max(ti["sum_hits"], 1)
                if l1_share < 0.20:
                    print(f"WARNING: L1 serves only {l1_share:.1%} of tiered "
                          f"hits at total_rows={c} (need >= 20%)",
                          file=sys.stderr)
                    failed = True
    if args.baseline:
        with open(args.baseline) as f:
            base_rec = json.load(f)
        for msg in check_baseline(rec, base_rec):
            print(f"REGRESSION: {msg}", file=sys.stderr)
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
