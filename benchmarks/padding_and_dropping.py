"""Static-shape overhead metrics (DESIGN.md §2 'changed assumptions').

1. Fanout-padding waste: the fixed-fanout padded tree trades ragged
   subgraphs for static shapes; the cost is masked (wasted) node slots.
   Measured on a power-law graph at the paper's (40, 20) fanouts via the
   depth-generic hop loop.

2. MoE capacity-drop rate: the capacity-factor dispatch drops assignments
   beyond each expert's queue; measured at the default factor 1.25 on a
   router with realistic (softmax-skewed) load.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generation import local_candidates
from repro.graph.subgraph import slots_per_seed
from repro.graph.synthetic import powerlaw_graph

FANOUTS = (40, 20)


def bench() -> list[tuple]:
    rows = []
    # --- padding waste (depth-generic hop loop) ---
    g = powerlaw_graph(50_000, avg_degree=10, n_hot=50, hot_degree=2_000, seed=0)
    indptr, indices = jnp.asarray(g.indptr), jnp.asarray(g.indices)
    seeds = jnp.asarray(
        np.random.default_rng(0).integers(0, 50_000, 512, dtype=np.int32))
    frontier = seeds
    parent_mask = np.ones(seeds.shape[0], dtype=bool)
    live = seeds.shape[0]
    hop_masks, hop_ids = [], []
    for level, k in enumerate(FANOUTS):
        c = local_candidates(indptr, indices, frontier, k,
                             jax.random.PRNGKey(level))
        m = np.isfinite(np.asarray(c.keys)) & parent_mask[:, None]
        hop_masks.append(m)
        hop_ids.append(np.asarray(c.ids))
        live += m.sum()
        frontier = jnp.where(jnp.asarray(m), c.ids, 0).reshape(-1)
        parent_mask = m.reshape(-1)
    total = seeds.shape[0] * slots_per_seed(FANOUTS)
    name = "padding_waste_fanout_" + "_".join(str(k) for k in FANOUTS)
    rows.append((name, 0.0, f"live_fraction={live/total:.3f}"))
    # with-replacement duplicate rate at hop 1 (hot nodes sample cleanly;
    # low-degree nodes repeat neighbors)
    ids1, m1 = hop_ids[0], hop_masks[0]
    uniq = np.mean([len(np.unique(ids1[i][m1[i]])) / max(m1[i].sum(), 1)
                    for i in range(ids1.shape[0])])
    rows.append(("sampling_unique_rate_hop1", 0.0, f"unique_fraction={uniq:.3f}"))

    # --- MoE drop rate ---
    from repro.configs import REGISTRY, smoke_config
    from repro.models import moe
    cfg = smoke_config(REGISTRY["qwen3-moe-30b-a3b"])
    p = jax.tree.map(lambda a: a[0], moe.init_moe_mlp(jax.random.PRNGKey(0), cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64, cfg.d_model))
    rate = float(moe.moe_drop_rate(p, x, cfg))
    rows.append(("moe_capacity_drop_rate", 0.0,
                 f"dropped={rate:.4f}@factor={moe.CAPACITY_FACTOR}"))
    return rows
