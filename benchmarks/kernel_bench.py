"""Kernel-layer microbenchmarks.

CPU wall times are for the jnp REFERENCE implementations (real compiled
code); Pallas kernels run in interpret mode here (TPU is the target) so
their timings are not comparable and are reported only as allclose checks
+ roofline-style derived metrics (arithmetic intensity of the op)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import time_fn


def bench() -> list[tuple]:
    rows = []
    # --- fanout_mean / gather_reduce (GCN aggregation hot spot) ---
    m, k, d = 4096, 20, 128
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k, d))
    mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.9, (m, k))
    f = jax.jit(ref.fanout_mean_ref)
    t = time_fn(f, x, mask)
    flops = 2 * m * k * d
    rows.append(("kernel_fanout_mean_ref", t,
                 f"ai={flops/(x.size*4+m*d*4):.2f}flops_per_byte"))
    got = ops.fanout_mean(x, mask, use_kernel=True)
    ok = np.allclose(np.asarray(got), np.asarray(f(x, mask)), rtol=1e-5, atol=1e-5)
    rows.append(("kernel_fanout_mean_pallas_interpret", 0.0, f"allclose={ok}"))

    # --- flash attention ---
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 1024, 64))
    kk = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 1024, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 1024, 64))
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, True))
    t = time_fn(f, q, kk, v)
    s, h, dh = 1024, 8, 64
    flops = 4 * h * s * s * dh
    rows.append(("kernel_attention_ref_1k", t,
                 f"gflops_cpu={flops/t*1e-3:.1f}"))
    got = ops.flash_attention(q, kk, v, causal=True, use_kernel=True)
    ok = np.allclose(np.asarray(got), np.asarray(f(q, kk, v)), rtol=2e-3, atol=2e-3)
    rows.append(("kernel_flash_attention_pallas_interpret", 0.0, f"allclose={ok}"))

    # --- SSD scan ---
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 512, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (2, 512, 4)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (4,)))
    bm = jax.random.normal(jax.random.PRNGKey(8), (2, 512, 64))
    cm = jax.random.normal(jax.random.PRNGKey(9), (2, 512, 64))
    from repro.models.ssm import ssd_chunked
    f_seq = jax.jit(ref.ssd_scan_ref)
    f_chunk = jax.jit(lambda *args: ssd_chunked(*args, 128))
    t_seq = time_fn(f_seq, x, dt, a, bm, cm, warmup=1, iters=3)
    t_chunk = time_fn(f_chunk, x, dt, a, bm, cm, warmup=1, iters=3)
    rows.append(("kernel_ssd_sequential_ref", t_seq, ""))
    rows.append(("kernel_ssd_chunked", t_chunk,
                 f"chunked_speedup={t_seq/t_chunk:.1f}x"))
    got = ops.ssd_scan(x[:1, :128], dt[:1, :128], a, bm[:1, :128], cm[:1, :128],
                       use_kernel=True, chunk=64)
    want = f_seq(x[:1, :128], dt[:1, :128], a, bm[:1, :128], cm[:1, :128])
    ok = np.allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
    rows.append(("kernel_ssd_pallas_interpret", 0.0, f"allclose={ok}"))
    return rows
