"""Paper §2(2): the balance table vs naive contiguous assignment.

Worker load = number of sampled-subgraph edge slots its seeds generate.
On a power-law graph with degree-correlated seed ordering (realistic: node
ids correlate with join date/degree in industrial graphs), contiguous
assignment concentrates hot seeds; the shuffled round-robin balance table
flattens it.  Metric: max/mean load skew (1.0 = perfect)."""
from __future__ import annotations

import numpy as np

from repro.core.balance import balance_table, load_skew
from repro.graph.synthetic import powerlaw_graph


def _worker_load(per_worker: np.ndarray, deg: np.ndarray, k1: int, k2: int):
    # per-seed work: 1-hop min(deg,k1) + 2-hop expansion
    cap1 = np.minimum(deg[per_worker], k1)
    return cap1.sum(axis=1) + (cap1 * k2).sum(axis=1)


def bench() -> list[tuple]:
    n, w = 50_000, 64
    k1, k2 = 40, 20
    g = powerlaw_graph(n, avg_degree=10, n_hot=100, hot_degree=5_000, seed=0)
    deg = g.degrees()
    order = np.argsort(-deg)          # id correlated with degree (hot first)
    seeds = order.astype(np.int32)

    # naive: contiguous blocks of the (degree-sorted) seed list
    per = len(seeds) // w
    naive = seeds[: per * w].reshape(w, per)
    skew_naive = load_skew(_worker_load(naive, deg, k1, k2))

    table = balance_table(seeds, w, seed=0)
    skew_bal = load_skew(_worker_load(table.per_worker, deg, k1, k2))

    return [
        ("load_skew_balance_table", 0.0,
         f"max_over_mean={skew_bal:.3f}"),
        ("load_skew_contiguous", 0.0,
         f"max_over_mean={skew_naive:.3f};improvement={skew_naive/skew_bal:.2f}x"),
    ]
