"""Autotuner vs calibration ladders: picked-config step time + rollbacks.

The autotuner's claim (docs/AUTOTUNING.md) is that ONE instrumented
trace window plus an offline cost-model search finds a configuration at
least as good as the serial calibration ladders — without paying a
device run per ladder rung.  This benchmark runs both paths end to end
on the W=4 smoke graph, then measures the picked configuration of each
in an identical warm generation loop:

  * ``ladder`` cell — ``calibrate_capacity_slack`` +
    ``calibrate_probe_hit_cap`` (the pre-autotune tuning path);
  * ``autotune`` cell — ``repro.launch.autotune.autotune_gcn`` (trace ->
    fit -> offline search -> live validator); a rejected pick falls back
    to the ladders and counts as a ROLLBACK.

Gates ``main`` enforces on the smoke configuration:

  * **step-time parity** — the model-picked config's best warm step
    time is <= 1.05x the ladder-picked config's (the search must not
    trade the ladders' device probes for a slower pick; the min over
    the warm window is the comparator because shared-runner scheduler
    noise only ever ADDS time);
  * **zero validator rollbacks** — on the smoke graph the trace-floored
    grid (``observed_floors``) must offer only picks the live validator
    accepts; a rollback here means the model proposed a config the
    traced workload already overflowed.

Each cell runs in a FRESH interpreter (``--cell``), the same hygiene as
``benchmarks/serve_latency.py``: the two paths must not share allocator
or JIT-cache state.

    PYTHONPATH=src python -m benchmarks.autotune [--smoke] \
        [--workers N] [--out BENCH_autotune.json]

Emits the ``name,us_per_call,derived`` CSV rows the harness expects
(``us_per_call`` is the cell's best warm step time).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: the step-time parity gate: model-picked <= this multiple of
#: ladder-picked (best warm step, same measurement loop)
PARITY_RATIO = 1.05


def _cell_env(workers: int) -> dict:
    """Child-process environment for one cell: the forced host device
    count must be in ``XLA_FLAGS`` before the child imports jax."""
    env = dict(os.environ)
    if workers > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={workers} "
            + env.get("XLA_FLAGS", ""))
    return env


def _run_cell(spec: dict) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.autotune",
           "--cell", json.dumps(spec)]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          env=_cell_env(spec.get("workers", 4)))
    if proc.returncode != 0:
        raise RuntimeError(f"cell {spec} failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure(*, path: str = "ladder", workers: int = 4, nodes: int = 4000,
            batch: int = 8, measure_steps: int = 24, trace_steps: int = 8,
            seed: int = 0) -> dict:
    """One cell: pick a config via ``path``, measure it warm.

    Both paths start from the same base configuration (fanouts, cache
    policy) and the same seed stream, and the picked config is measured
    by the SAME loop — the comparison isolates the tuning method, not
    the measurement harness.  An autotune rejection falls back to the
    ladder pick and reports ``rollbacks=1``."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.balance import balance_table
    from repro.core.feature_cache import CacheConfig
    from repro.core.generation import make_distributed_generator
    from repro.core.partition import partition_edges
    from repro.graph.synthetic import (node_features, node_labels,
                                       powerlaw_graph)
    from repro.launch.autotune import autotune_gcn, candidate_cache_cfg
    from repro.launch.mesh import make_mesh
    from repro.launch.train import (CALIBRATION_PROBES,
                                    calibrate_capacity_slack,
                                    calibrate_probe_hit_cap)

    w, dim = workers, 16
    mesh = make_mesh((w,), ("data",))
    g = powerlaw_graph(nodes, avg_degree=8,
                       n_hot=max(nodes // 1000, 1), seed=seed)
    part = partition_edges(g, w)
    feats = node_features(nodes, dim)
    labels = node_labels(nodes, 5)
    table = balance_table(np.arange(nodes), w, seed)
    fanouts = (3, 4)
    base_cfg = CacheConfig(256, admit=1, assoc=2, mode="sharded",
                           wire="compact")
    n_rngs = max(measure_steps, trace_steps, CALIBRATION_PROBES)
    rngs = jax.random.split(jax.random.PRNGKey(seed + 1), n_rngs)

    def seeds_for(t):
        cols = (np.arange(batch) + t * batch) % table.per_worker.shape[1]
        return jnp.asarray(table.per_worker[:, cols])

    rollbacks = 0
    picked = None
    if path == "autotune":
        res = autotune_gcn(mesh, part, feats, labels, fanouts=fanouts,
                           cache_cfg=base_cfg, feature_store="device",
                           batch_per_worker=batch, seeds_for=seeds_for,
                           rngs=rngs, steps=trace_steps, slack=2.0)
        if res.accepted:
            cand = res.candidate
            picked = (cand.fanouts, float(cand.capacity_slack),
                      candidate_cache_cfg(base_cfg, cand))
        else:
            rollbacks = 1
            print(f"autotune cell: rollback — {res.reason}",
                  file=sys.stderr)
    if picked is None:
        probes = [(seeds_for(t), rngs[t])
                  for t in range(CALIBRATION_PROBES)]
        _, cal_args = make_distributed_generator(mesh, part, feats,
                                                 labels, fanouts=fanouts)
        slack = calibrate_capacity_slack(mesh, cal_args, fanouts, probes,
                                         cache_cfg=base_cfg)
        cfg = calibrate_probe_hit_cap(mesh, cal_args, fanouts, probes,
                                      slack, base_cfg)
        picked = (fanouts, slack, cfg)

    fo, slack, cfg = picked
    gen_fn, device_args, cache = make_distributed_generator(
        mesh, part, feats, labels, fanouts=fo, capacity_slack=slack,
        cache_cfg=cfg)
    times = []
    dropped = demoted = 0
    for t in range(measure_steps):
        t0 = time.perf_counter()
        out, cache = gen_fn(device_args, seeds_for(t), rngs[t], cache)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
        dropped += int(np.asarray(out.n_dropped).sum())
        demoted += int(np.asarray(out.n_probe_demoted).sum())
    warm = sorted(times[measure_steps // 2:])
    return {
        "path": path, "workers": w, "nodes": nodes,
        # best warm step: same-work comparisons on a shared CPU runner
        # are far less jittery at the min than at the median (scheduler
        # noise only ever ADDS time); the median rides along as context
        "step_us": warm[0] * 1e6,
        "step_us_p50": warm[len(warm) // 2] * 1e6,
        "rollbacks": rollbacks,
        "dropped": dropped, "demoted": demoted,
        "fanouts": list(fo), "capacity_slack": slack,
        "cache_rows": cfg.n_rows, "assoc": cfg.assoc,
        "hit_cap": cfg.hit_cap, "wire": cfg.wire,
    }


def sweep(*, smoke: bool = False, workers: int = 4,
          seed: int = 0) -> dict:
    """The ladder and autotune cells, each in a fresh interpreter."""
    nodes = 4000 if smoke else 20_000
    results = [
        _run_cell(dict(path=p, workers=workers, nodes=nodes, seed=seed))
        for p in ("ladder", "autotune")
    ]
    ladder, tuned = results
    return {
        "benchmark": "autotune",
        "workers": workers,
        "nodes": nodes,
        "parity_ratio_gate": PARITY_RATIO,
        "step_ratio": tuned["step_us"] / ladder["step_us"],
        "results": results,
    }


def bench() -> list:
    """Harness entry (benchmarks.run): smoke-size sweep, CSV rows
    (``us_per_call`` is the cell's best warm step time)."""
    rec = sweep(smoke=True, workers=4)
    return [
        (f"autotune_{r['path']}", r["step_us"],
         f"rollbacks={r['rollbacks']},dropped={r['dropped']},"
         f"slack={r['capacity_slack']},hit_cap={r['hit_cap']},"
         f"rows={r['cache_rows']}")
        for r in rec["results"]
    ]


def main() -> None:
    """CLI: run the sweep, print CSV rows, enforce the autotune gates."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes (the CI configuration)")
    ap.add_argument("--workers", type=int, default=4,
                    help="forced host devices (the W=4 smoke gate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--cell", default=None,
                    help="(internal) measure one cell from a JSON spec "
                         "and print its result — how sweep() isolates "
                         "cells in fresh interpreters")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(measure(**json.loads(args.cell))))
        return

    rec = sweep(smoke=args.smoke, workers=args.workers, seed=args.seed)
    print("name,us_per_call,derived")
    for r in rec["results"]:
        print(f"autotune_{r['path']},{r['step_us']:.1f},"
              f"rollbacks={r['rollbacks']},dropped={r['dropped']},"
              f"demoted={r['demoted']},fanouts={r['fanouts']},"
              f"slack={r['capacity_slack']},rows={r['cache_rows']},"
              f"hit_cap={r['hit_cap']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.out}", file=sys.stderr)

    ladder, tuned = rec["results"]
    failed = False
    # zero-rollback gate: on the smoke graph the floored grid must only
    # offer picks the live validator accepts — a rollback means the
    # model proposed a config the traced workload already overflowed
    if tuned["rollbacks"] != 0:
        print(f"WARNING: autotune rolled back to the ladders "
              f"{tuned['rollbacks']} time(s) on the smoke graph — the "
              f"observed_floors grid filter is not doing its job",
              file=sys.stderr)
        failed = True
    # parity gate: the offline search must not trade the ladders'
    # device probes for a slower pick (ratio-based: runner drift
    # cannot flip it)
    if rec["step_ratio"] > PARITY_RATIO:
        print(f"WARNING: model-picked config is "
              f"{rec['step_ratio']:.3f}x the ladder-picked step time "
              f"(> {PARITY_RATIO}x gate): ladder "
              f"{ladder['step_us']:.0f}us vs autotune "
              f"{tuned['step_us']:.0f}us", file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
