"""Benchmark harness — one module per paper table/claim (DESIGN.md §5).

    PYTHONPATH=src python -m benchmarks.run [--scale] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", action="store_true",
                    help="include the 1M-nodes-per-iteration configuration")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (autotune, feature_cache, gen_throughput, host_fetch,
                   kernel_bench, load_balance, padding_and_dropping,
                   pipeline_overlap, serve_latency, tree_reduce_bench)

    suites = {
        "gen_throughput": lambda: gen_throughput.bench(scale=False),
        "load_balance": load_balance.bench,
        "pipeline_overlap": pipeline_overlap.bench,
        "tree_reduce": tree_reduce_bench.bench,
        "kernels": kernel_bench.bench,
        "padding_and_dropping": padding_and_dropping.bench,
        "feature_cache": feature_cache.bench,
        "host_fetch": host_fetch.bench,
        "serve_latency": serve_latency.bench,
        "autotune": autotune.bench,
    }
    if args.scale:
        suites["gen_throughput_1M"] = lambda: gen_throughput.bench(scale=True)

    print("name,us_per_call,derived")
    failed = False
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        try:
            emit(fn())
        except Exception:
            failed = True
            print(f"{name},0.0,ERROR", file=sys.stdout)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
